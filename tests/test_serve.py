"""Placement service: cache semantics, micro-batching, escalation ladder.

The integration test drives the full ladder under a simulated clock, so
latency/hit-rate assertions are exact functions of the request trace.
The contention-mode tests pin the provenance rule: the topology digest
carries the simulator mode, and a mode flip over a warm store re-infers
with ``stale_served == 0`` — exactly like a policy bump.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.featurize import bucket_size, featurize
from repro.core.graph import topo_relabel
from repro.core.policy import PolicyConfig
from repro.core.ppo import PPOConfig, PPOTrainer
from repro.graphs import synthetic as S
from repro.serve import (MicroBatcher, PlacementService, PlacementCache,
                         PersistentStore, ServeConfig, SimulatedClock,
                         policy_hash, topology_fingerprint)
from repro.serve.cache import CacheEntry
from repro.sim.device import p100_topology
from repro.sim.reference import simulate_ref


def _entry(mk, pl_len=4):
    return CacheEntry(np.zeros(pl_len, np.int32), mk, mk)


# ------------------------------------------------------------------- cache
def test_cache_lru_eviction_and_stats():
    c = PlacementCache(capacity=2, policy="lru")
    c.put(("a", "t"), _entry(1.0))
    c.put(("b", "t"), _entry(2.0))
    assert c.get(("a", "t")) is not None      # refresh a
    c.put(("c", "t"), _entry(3.0))            # evicts b (LRU)
    assert c.get(("b", "t")) is None
    assert c.get(("c", "t")) is not None
    assert c.stats.evictions == 1
    assert c.stats.hits == 2 and c.stats.misses == 1
    assert c.stats.hit_rate == pytest.approx(2 / 3)


def test_cache_lfu_prefers_hot_entries():
    c = PlacementCache(capacity=2, policy="lfu")
    c.put(("hot", "t"), _entry(1.0))
    for _ in range(5):
        assert c.get(("hot", "t")) is not None
    c.put(("cold", "t"), _entry(2.0))
    c.put(("new", "t"), _entry(3.0))          # evicts cold (0 hits), not hot
    assert c.peek(("hot", "t")) is not None
    assert c.peek(("cold", "t")) is None


def test_cache_publish_is_monotone():
    c = PlacementCache(capacity=4)
    key = ("g", "t")
    assert c.publish(key, np.zeros(4, np.int32), 2.0, source="zero_shot")
    assert not c.publish(key, np.ones(4, np.int32), 2.5)   # regression refused
    assert c.peek(key).measured_makespan == 2.0
    assert c.publish(key, np.ones(4, np.int32), 1.5, source="finetuned")
    e = c.peek(key)
    assert e.measured_makespan == 1.5 and e.source == "finetuned"
    assert np.all(e.placement == 1)


# ----------------------------------------------------------------- batcher
def _gb(g, topo):
    return featurize(g, max_deg=8, topo=topo)


def test_batcher_flushes_full_groups_and_backfills():
    topo = p100_topology(4)
    g = S.rnnlm(2, time_steps=3)
    mb = MicroBatcher(max_batch=3, max_wait_s=1.0)
    key = MicroBatcher.group_key("tfp", 4, g.num_nodes)
    for i in range(4):
        mb.add(key, f"r{i}", _gb(g, topo), now=0.0)
    flushes = mb.ready(now=0.0)
    assert len(flushes) == 1 and flushes[0].real == 3      # full batch only
    assert len(mb) == 1
    fl = mb.ready(now=2.0)[0]                              # timeout flush
    assert fl.real == 1
    # batch dim always padded to max_batch; node dim to the bucket
    assert fl.sgb.op.shape == (3, bucket_size(g.num_nodes))
    assert fl.sgb.nbr_idx.shape[2] == 16                   # pinned 2*max_deg
    assert len(mb) == 0


def test_batcher_groups_by_compiled_shape():
    topo = p100_topology(4)
    small, big = S.rnnlm(2, time_steps=3), S.rnnlm(2, time_steps=8)
    assert bucket_size(small.num_nodes) != bucket_size(big.num_nodes)
    mb = MicroBatcher(max_batch=4, max_wait_s=0.0)
    for g in (small, big):
        mb.add(MicroBatcher.group_key("tfp", 4, g.num_nodes), g.name,
               _gb(g, topo), now=0.0)
    flushes = mb.ready(now=0.0)
    assert len(flushes) == 2                               # one per bucket
    assert {f.sgb.op.shape[1] for f in flushes} == \
        {bucket_size(small.num_nodes), bucket_size(big.num_nodes)}


# ---------------------------------------------------- escalation ladder
def _relabeled(g, seed):
    rng = np.random.RandomState(seed)
    perm = rng.permutation(g.num_nodes)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(g.num_nodes)
    return topo_relabel(g.name + "-rl", g.op_type[perm], g.flops[perm],
                        g.out_bytes[perm], g.mem_bytes[perm],
                        g.out_shape[perm], inv[g.src], inv[g.dst])


def test_escalation_ladder_under_simulated_clock():
    """Zipf-skewed stream: steady-state hit rate is exact, latencies follow
    the deterministic cost model, and fine-tune escalation strictly
    improves the cached makespan it republishes."""
    pcfg = PolicyConfig(hidden=32, gnn_layers=2, placer_layers=1, ffn=64,
                        window=32, max_devices=8)
    ppo = PPOConfig(num_samples=8, epochs=1)
    trainer = PPOTrainer(pcfg, ppo, seed=0)
    cfg = ServeConfig(max_batch=1, num_samples=2, simulated=True,
                      finetune_iters=6, escalate_margin=0.0, seed=0)
    clock = SimulatedClock()
    svc = PlacementService(trainer, cfg, clock)

    g_hot = S.rnnlm(2, time_steps=3)
    g_cold = topo_relabel("rnnlm-scaled", g_hot.op_type, g_hot.flops * 1.5,
                          g_hot.out_bytes, g_hot.mem_bytes, g_hot.out_shape,
                          g_hot.src, g_hot.dst)
    topo = p100_topology(4).tightened(g_hot.total_mem())

    # zipf-ish two-key stream: hot key (incl. relabelings) dominates
    trace = [g_hot, g_cold, _relabeled(g_hot, 1), g_hot, _relabeled(g_hot, 2),
             g_cold, g_hot, _relabeled(g_hot, 3), g_hot, g_cold,
             _relabeled(g_hot, 4), g_hot]
    reqs = []
    zs_after_first = {}
    for i, g in enumerate(trace):
        r = svc.submit(g, topo, arrival_t=i * 1.0)
        reqs.append(r)
        if r.key not in zs_after_first and svc.cache.peek(r.key) is not None:
            zs_after_first[r.key] = \
                svc.cache.peek(r.key).measured_makespan
        svc.step()      # async worker turn: lets fine-tunes land mid-trace
    svc.drain()

    # ---- steady-state hit rate: exactly 2 misses (one per unique key)
    stats = svc.stats()
    assert stats["misses"] == 2
    assert stats["hit_rate"] == pytest.approx((len(trace) - 2) / len(trace))
    second_half = reqs[len(reqs) // 2:]
    assert all(r.source == "cache" for r in second_half)

    # ---- deterministic latencies from the service-time model
    c = cfg.costs
    for r in reqs:
        if r.source == "cache":
            assert r.latency == pytest.approx(c.lookup_s)
        else:
            assert r.latency == pytest.approx(
                c.lookup_s + c.batch_base_s + c.batch_per_graph_s)

    # ---- every response is a feasible placement of the right arity
    for r in reqs:
        assert np.isfinite(r.makespan)
        assert r.placement.shape == (r.graph.num_nodes,)
        assert r.placement.min() >= 0 and r.placement.max() < 4

    # ---- escalation ran and only ever improved the cached entries
    assert svc.counts["finetunes"] >= 1
    assert svc.counts["finetune_published"] >= 1
    improved = 0
    for key, zs_mk in zs_after_first.items():
        entry = svc.cache.peek(key)
        assert entry.measured_makespan <= zs_mk + 1e-12
        if entry.source == "finetuned":
            assert entry.measured_makespan < zs_mk   # strict improvement
            improved += 1
    assert improved >= 1
    # cache hits after the publish serve the fine-tuned makespan
    ft_served = [r for r in reqs if r.entry_source == "finetuned"]
    for r in ft_served:
        key_entry = svc.cache.peek(r.key)
        assert r.makespan == pytest.approx(key_entry.measured_makespan)


# ------------------------------------------------- contention-aware serving
def _small_trainer(seed=0):
    return PPOTrainer(PolicyConfig(hidden=32, gnn_layers=2, placer_layers=1,
                                   ffn=64, window=32, max_devices=8),
                      PPOConfig(num_samples=8, epochs=1), seed=seed)


def test_topology_digest_carries_contention_mode():
    """Two simulator modes never share a cache key; contention-off is the
    historical digest bit-for-bit."""
    topo = p100_topology(4)
    off = topology_fingerprint(topo)
    on = topology_fingerprint(topo, sender_contention=True)
    assert off != on
    assert off == topology_fingerprint(topo, sender_contention=False)
    # an equal topology (fresh object) digests identically per mode
    topo2 = p100_topology(4)
    assert topology_fingerprint(topo2) == off
    assert topology_fingerprint(topo2, sender_contention=True) == on


def test_contention_service_judges_with_contended_simulator():
    """A contention-mode worker's reported makespan is the *contended*
    makespan of the placement it returns (numpy-oracle cross-check), and
    its keys are disjoint from an off-mode worker's."""
    g = S.rnnlm(2, time_steps=3)
    topo = p100_topology(4).tightened(g.total_mem())
    cfg = ServeConfig(max_batch=1, num_samples=2, simulated=True,
                      finetune_iters=0, seed=0, sender_contention=True)
    svc = PlacementService(_small_trainer(), cfg, SimulatedClock())
    r = svc.submit(g, topo, arrival_t=0.0)
    svc.drain()
    assert r.source in ("zero_shot", "baseline")
    mk_ref, _, valid = simulate_ref(g, r.placement, topo,
                                    sender_contention=True)
    assert valid and np.isclose(r.makespan, mk_ref, rtol=1e-4)

    svc_off = PlacementService(_small_trainer(),
                               dataclasses.replace(cfg,
                                                   sender_contention=False),
                               SimulatedClock())
    r_off = svc_off.submit(g, topo, arrival_t=0.0)
    svc_off.drain()
    assert r_off.key[0] == r.key[0]        # same graph fingerprint
    assert r_off.key[1] != r.key[1]        # different topology digest


def test_contention_mode_flip_reinfers_with_zero_stale(tmp_path):
    """A warm store written contention-off must be fully invalidated by a
    contention-on restart (same policy!): every request re-infers, the
    stale_served audit stays 0, and flipping back still sees the
    original records."""
    trainer = _small_trainer()
    ph = policy_hash(trainer.state.params)
    graphs = [S.rnnlm(2, time_steps=3), S.rnnlm(2, time_steps=4)]
    topo = p100_topology(4)
    topo = topo.with_mem_caps(max(g.total_mem() for g in graphs) * 2)
    cfg = ServeConfig(max_batch=1, num_samples=2, simulated=True,
                      finetune_iters=0, seed=0)

    store = PersistentStore(tmp_path, ph)
    svc = PlacementService(trainer, cfg, SimulatedClock(), store=store)
    for i, g in enumerate(graphs):
        svc.submit(g, topo, arrival_t=float(i))
    svc.shutdown()
    written = store.stats.records_written
    assert written >= len(graphs)

    # mode flip: same policy, contended simulator (shutdown compaction
    # merged the publish+snapshot duplicates down to one record per key)
    store_on = PersistentStore(tmp_path, ph, worker_tag="w1",
                               sender_contention=True)
    assert store_on.stats.records_invalidated == len(graphs)
    assert len(store_on) == 0              # nothing fresh to serve
    cfg_on = dataclasses.replace(cfg, sender_contention=True)
    svc_on = PlacementService(trainer, cfg_on, SimulatedClock(),
                              store=store_on)
    assert len(svc_on.cache) == 0          # no cross-mode warm start
    srcs = []
    for i, g in enumerate(graphs):
        srcs.append(svc_on.submit(g, topo, arrival_t=float(i)).source)
    svc_on.shutdown()
    assert all(s in ("zero_shot", "baseline") for s in srcs)   # re-inferred
    assert svc_on.counts["stale_served"] == 0
    assert svc_on.counts["cache"] == 0 and svc_on.counts["disk"] == 0

    # flipping back: off-mode records are fresh again, on-mode ones are not
    store_back = PersistentStore(tmp_path, ph, worker_tag="w2")
    assert len(store_back) >= len(graphs)
    assert store_back.stats.records_invalidated >= len(graphs)  # on-mode recs


def test_service_refuses_cross_mode_store(tmp_path):
    """A service must not warm-start from a store replaying the other
    simulator mode."""
    trainer = _small_trainer()
    store = PersistentStore(tmp_path, policy_hash(trainer.state.params),
                            sender_contention=True)
    with pytest.raises(AssertionError):
        PlacementService(trainer, ServeConfig(simulated=True), store=store)


# ------------------------------------------------- jumbo bucket + rejection
def test_service_sheds_oversized_requests_typed():
    """Out-of-bounds requests degrade to the baseline fast path with a
    typed Rejection instead of crashing the worker on an assert."""
    trainer = _small_trainer()
    cfg = ServeConfig(simulated=True, max_graph_nodes=100)
    svc = PlacementService(trainer, cfg, SimulatedClock())

    # too many devices for the policy head (max_devices=8)
    g = S.rnnlm(2, time_steps=3)
    wide = p100_topology(12).tightened(g.total_mem())
    r1 = svc.submit(g, wide, arrival_t=0.0)
    assert r1.source == "shed"
    assert r1.rejection.reason == "too_many_devices"
    assert r1.rejection.limit == 8 and r1.rejection.requested == 12
    assert r1.placement.shape == (g.num_nodes,)
    assert r1.placement.max() < 12 and np.isnan(r1.makespan)

    # graph above the worker's jumbo bound
    big = S.rnnlm(2, time_steps=5)
    assert big.num_nodes > 100
    topo = p100_topology(4).tightened(big.total_mem())
    r2 = svc.submit(big, topo, arrival_t=1.0)
    assert r2.source == "shed"
    assert r2.rejection.reason == "graph_too_large"
    assert r2.placement.shape == (big.num_nodes,)

    assert svc.counts["shed_rejected"] == 2
    assert svc.counts["shed"] == 2
    # the worker is still healthy: a normal request resolves
    ok = svc.submit(g, p100_topology(4).tightened(g.total_mem()),
                    arrival_t=2.0)
    svc.drain()
    assert ok.source in ("zero_shot", "baseline")
    assert np.isfinite(ok.makespan)


def test_service_jumbo_bucket_admission():
    """Graphs above jumbo_threshold skip the micro-batcher: they are
    segment-padded (featurize.jumbo_bucket, not the power-of-two ladder)
    and served solo; the result is cached so repeats hit."""
    from repro.core.featurize import jumbo_bucket as jb
    pcfg = PolicyConfig(hidden=32, gnn_layers=2, placer_layers=1, ffn=64,
                        window=32, max_devices=8, segment=32, gnn_chunk=64)
    trainer = PPOTrainer(pcfg, PPOConfig(num_samples=4, epochs=1), seed=0)
    cfg = ServeConfig(simulated=True, num_samples=2,
                      jumbo_threshold=64, jumbo_pad_multiple=64,
                      finetune_iters=0)
    svc = PlacementService(trainer, cfg, SimulatedClock())
    g = S.rnnlm(2, time_steps=3)          # 72 nodes > 64 threshold
    assert g.num_nodes > cfg.jumbo_threshold
    topo = p100_topology(4).tightened(g.total_mem())
    r = svc.submit(g, topo, arrival_t=0.0)
    assert svc.counts["jumbo"] == 1
    assert r.source in ("zero_shot", "baseline")
    assert r.placement.shape == (g.num_nodes,)
    assert np.isfinite(r.makespan)
    # context arrays live at the segment-aligned jumbo bucket
    ctx = svc._ctx[r.key]
    assert ctx.gb.op.shape[0] == jb(g.num_nodes, 64)
    assert ctx.gb.op.shape[0] % pcfg.segment == 0
    # repeat traffic rides the cache, not another decode
    r2 = svc.submit(g, topo, arrival_t=1.0)
    assert r2.source == "cache"
    assert svc.counts["jumbo"] == 1


def test_admission_sheds_oversize_at_router():
    """Router-level jumbo shedding: AdmissionController counts and
    refuses graphs above max_graph_nodes before they reach a worker."""
    from repro.serve import AdmissionConfig, AdmissionController
    ac = AdmissionController(AdmissionConfig(max_graph_nodes=50))
    assert ac.admit(lag_s=0.0, queue_depth=0, num_nodes=10)
    assert not ac.admit(lag_s=0.0, queue_depth=0, num_nodes=51)
    assert ac.stats.shed_oversize == 1
    assert ac.stats.shed == 1
    assert ac.stats.as_dict()["shed_oversize"] == 1


# --------------------------------------------------------- retrace pinning
def _flops_scaled(g, factor):
    """Same topology/size, different content hash: a distinct cache key
    that lands in the same compiled bucket."""
    return topo_relabel(f"{g.name}-x{factor}", g.op_type, g.flops * factor,
                        g.out_bytes, g.mem_bytes, g.out_shape, g.src, g.dst)


def test_one_compile_per_bucket_on_warm_replay():
    """Retrace regression pin: a warm 20-request replay across two serving
    buckets adds ZERO new jit programs.  Each request is a distinct cache
    key (flops-scaled variant), so every one runs real batched inference —
    but the sampler compiles once per (bucket, devices, samples) config,
    never per graph.  Module-level jit caches persist across tests, so the
    pin is on deltas, not absolute cache sizes."""
    from repro.obs import jaxprof

    trainer = _small_trainer()
    cfg = ServeConfig(max_batch=1, num_samples=2, simulated=True,
                      finetune_iters=0, seed=0)
    svc = PlacementService(trainer, cfg, SimulatedClock())
    g_a = S.rnnlm(2, time_steps=3)        # 72 nodes  -> bucket 128
    g_b = S.rnnlm(2, time_steps=12)       # 261 nodes -> bucket 512
    assert bucket_size(g_a.num_nodes) != bucket_size(g_b.num_nodes)
    topo = p100_topology(4)

    t = [0.0]

    def submit(g):
        r = svc.submit(g, topo, arrival_t=t[0])
        t[0] += 1.0
        svc.drain()
        return r

    # cold: first request in each bucket compiles at most one program each
    mon_cold = jaxprof.RetraceMonitor()
    submit(g_a)
    submit(g_b)
    assert mon_cold.delta().get("serve.sample_batch", 0) <= 2

    # warm replay: 20 fresh keys across the two warmed buckets
    mon = jaxprof.RetraceMonitor()
    for i in range(10):
        ra = submit(_flops_scaled(g_a, 1.0 + 0.01 * (i + 1)))
        rb = submit(_flops_scaled(g_b, 1.0 + 0.01 * (i + 1)))
        assert ra.source == "zero_shot" and rb.source == "zero_shot"
    assert svc.counts["zero_shot"] >= 22          # replay ran real inference
    assert mon.delta() == {}                      # zero new compiles anywhere
