"""Segment invariance: segmented decode/featurize/simulate == monolithic.

The architecture invariant (docs/architecture.md): segment size NEVER
changes results — only compiled shapes.  These tests pin it bit-for-bit
on small golden graphs across both contention modes and uniform + hetero
topologies, plus the serving-tier jumbo admission/rejection paths.

(The teacher-forced pins compare the *jitted* monolithic pass against the
segmented pass: both production paths are compiled, and XLA's eager
dispatch rounds a few ULP differently than its fused programs.)
"""
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gnn, placer as PL, policy as P
from repro.core.featurize import featurize, jumbo_bucket
from repro.core.policy import PolicyConfig
from repro.core.ppo import PPOConfig, PPOTrainer
from repro.graphs import synthetic as S
from repro.sim import p100_topology
from repro.sim.device import multi_gen_fleet
from repro.sim.scheduler import (Env, SimTopology, prepare_sim_graph,
                                 simulate)
from repro.sim.reference import simulate_ref

CFG = PolicyConfig(hidden=32, gnn_layers=2, placer_layers=2, ffn=64,
                   window=32, max_devices=8)
SEG = 16


def _topos(g):
    return {
        "uniform": p100_topology(4).with_mem_caps(g.total_mem()),
        "hetero": multi_gen_fleet().tightened(g.total_mem()),
    }


@pytest.fixture(scope="module")
def setup():
    g = S.rnnlm(2, time_steps=3)
    topo = p100_topology(4)
    gb = featurize(g, max_deg=8, topo=topo)
    params = P.init(jax.random.PRNGKey(0), CFG)
    return g, gb, params


# ------------------------------------------------------------- AR decode
@pytest.mark.parametrize("seg", [8, 16, 32, 100])
def test_sample_segmented_bitwise(setup, seg):
    """Segmented AR sampling draws the SAME placements with the SAME
    logp as the monolithic scan — same step function, same keys, carried
    state across segment boundaries."""
    _, gb, params = setup
    cfg_seg = dataclasses.replace(CFG, segment=seg, gnn_chunk=seg)
    key = jax.random.PRNGKey(1)
    pl_m, lp_m = P.sample(params, CFG, gb, 4, key, 3)
    pl_s, lp_s = P.sample(params, cfg_seg, gb, 4, key, 3)
    assert np.array_equal(np.asarray(pl_m), np.asarray(pl_s))
    assert np.array_equal(np.asarray(lp_m), np.asarray(lp_s))


def test_sample_segmented_bitwise_hetero(setup):
    """Same pin with a heterogeneous capability table conditioning the
    decoder head."""
    g, _, params = setup
    topo = multi_gen_fleet().tightened(g.total_mem())
    gb = featurize(g, max_deg=8, topo=topo)
    cfg_seg = dataclasses.replace(CFG, segment=SEG)
    key = jax.random.PRNGKey(3)
    pl_m, lp_m = P.sample(params, CFG, gb, topo.num_devices, key, 2)
    pl_s, lp_s = P.sample(params, cfg_seg, gb, topo.num_devices, key, 2)
    assert np.array_equal(np.asarray(pl_m), np.asarray(pl_s))
    assert np.array_equal(np.asarray(lp_m), np.asarray(lp_s))


# ------------------------------------------------------- teacher-forced
@pytest.mark.parametrize("seg", [8, 16, 64])
def test_tf_segmented_bitwise(setup, seg):
    """Segmented teacher-forced logits == jitted monolithic logits,
    bit-for-bit, for any segment size (the Transformer-XL memory hands
    each node exactly the W-band the banded pass gathers)."""
    _, gb, params = setup
    h = gnn.apply(params["gnn"], gb)
    from repro.core import superposition
    c = superposition.gain(params["sp"],
                           gnn.graph_summary(h, gb.node_mask))
    key = jax.random.PRNGKey(2)
    pl, _ = P.sample(params, CFG, gb, 4, key, 1)
    pl = pl[0]
    tf_jit = jax.jit(partial(PL.apply_tf, window=CFG.window,
                             heads=CFG.heads, num_devices=4))
    lg_m = tf_jit(params["placer"], h, gb.node_mask, pl, c, gb.mem_frac,
                  gb.comp_frac, gb.dev_feats)
    lg_s = PL.apply_tf_segmented(params["placer"], h, gb.node_mask, pl, c,
                                 gb.mem_frac, gb.comp_frac, gb.dev_feats,
                                 segment=seg, window=CFG.window,
                                 heads=CFG.heads, num_devices=4)
    assert np.array_equal(np.asarray(lg_m), np.asarray(lg_s))


def test_logp_segmented_matches_monolithic(setup):
    """Policy-level PPO ratio path: per-node logp from the segmented TF
    pass equals the monolithic one to float tolerance on real nodes."""
    _, gb, params = setup
    cfg_seg = dataclasses.replace(CFG, segment=SEG)
    pl, _ = P.sample(params, CFG, gb, 4, jax.random.PRNGKey(4), 2)
    lp_m, ent_m = P.logp_and_entropy(params, CFG, gb, 4, pl)
    lp_s, ent_s = P.logp_and_entropy(params, cfg_seg, gb, 4, pl)
    np.testing.assert_allclose(np.asarray(lp_m), np.asarray(lp_s),
                               atol=1e-5, rtol=0)
    assert abs(float(ent_m) - float(ent_s)) < 1e-5


# -------------------------------------------------------- featurization
def test_gnn_chunked_bitwise(setup):
    """Chunked neighbor aggregation == one-shot, bit-for-bit, including
    a chunk that does not divide N (internal padding)."""
    _, gb, params = setup
    h0 = gnn.apply(params["gnn"], gb)
    for chunk in (8, 37, 64):
        h1 = gnn.apply(params["gnn"], gb, chunk=chunk)
        assert np.array_equal(np.asarray(h0), np.asarray(h1)), chunk


def test_gnn_chunked_bitwise_pallas(setup):
    """The pallas row-blocked kernel path agrees with its own one-shot
    densified path bit-for-bit (interpret mode on CPU)."""
    _, gb, params = setup
    h0 = gnn.apply(params["gnn"], gb, agg_impl="pallas")
    h1 = gnn.apply(params["gnn"], gb, agg_impl="pallas", chunk=64)
    assert np.array_equal(np.asarray(h0), np.asarray(h1))


def test_featurize_pad_multiple():
    g = S.rnnlm(2, time_steps=3)
    gb = featurize(g, max_deg=8, pad_multiple=64)
    assert gb.op.shape[0] % 64 == 0
    assert gb.op.shape[0] >= g.num_nodes
    assert gb.num_nodes == g.num_nodes
    assert jumbo_bucket(50_001, 2048) == 51_200


# ------------------------------------------------------------- simulate
@pytest.mark.parametrize("contention", [False, True])
@pytest.mark.parametrize("fleet", ["uniform", "hetero"])
def test_simulate_segmented_bitwise(contention, fleet):
    """Segment-batched simulate == monolithic simulate, bit-for-bit, and
    both match the numpy oracle — both contention modes, uniform and
    heterogeneous fleets."""
    g = S.gnmt(2, time_steps=4)
    topo = _topos(g)[fleet]
    st = SimTopology.from_topology(topo)
    sg_m = prepare_sim_graph(g, topo, max_deg=16)
    sg_s = prepare_sim_graph(g, topo, max_deg=16, pad_multiple=32)
    assert sg_s.compute_t.shape[0] % 32 == 0
    rng = np.random.RandomState(0)
    for _ in range(3):
        pl = rng.randint(0, topo.num_devices,
                         size=sg_s.compute_t.shape[0]).astype(np.int32)
        mk_m, u_m, v_m = simulate(sg_m, jnp.asarray(pl[:g.num_nodes]), st,
                                  contention)
        mk_s, u_s, v_s = simulate(sg_s, jnp.asarray(pl), st, contention,
                                  segment=32)
        assert float(mk_m) == float(mk_s)
        assert float(u_m) == float(u_s)
        assert bool(v_m) == bool(v_s)
        ref_mk, _, _ = simulate_ref(g, pl[:g.num_nodes], topo,
                                    sender_contention=contention)
        np.testing.assert_allclose(float(mk_s), ref_mk, rtol=1e-5)


@pytest.mark.parametrize("contention", [False, True])
def test_env_segment_threading(contention):
    """Env(segment=...) returns the same rewards as the monolithic env
    over the same padded arrays (the jit wrapper keys on the mode)."""
    g = S.rnnlm(2, time_steps=3)
    topo = p100_topology(4).with_mem_caps(g.total_mem())
    sg = prepare_sim_graph(g, topo, max_deg=16, pad_multiple=16)
    env_m = Env(sg, topo, sender_contention=contention)
    env_s = Env(sg, topo, sender_contention=contention, segment=16)
    rng = np.random.RandomState(1)
    pls = rng.randint(0, 4, size=(4, sg.compute_t.shape[0])).astype(np.int32)
    mk_m, r_m, v_m = env_m.rewards(pls)
    mk_s, r_s, v_s = env_s.rewards(pls)
    assert np.array_equal(np.asarray(mk_m), np.asarray(mk_s))
    assert np.array_equal(np.asarray(r_m), np.asarray(r_s))
    assert np.array_equal(np.asarray(v_m), np.asarray(v_s))


# ----------------------------------------------------- segmented PPO run
def test_segmented_ppo_iteration_runs():
    """A segment-native PPO fine-tune iteration (eager orchestration,
    per-segment compiled programs) trains end-to-end on a segment-padded
    task and produces finite, valid makespans."""
    from benchmarks import common as C
    pcfg = dataclasses.replace(CFG, segment=SEG, gnn_chunk=SEG)
    ppo = PPOConfig(num_samples=4, epochs=1)
    g = S.rnnlm(2, time_steps=3)
    task = C.make_task("seg-ppo", g, 4, segment=SEG)
    tr = PPOTrainer(pcfg, ppo, seed=0)
    m = tr.iteration(task.name, task.gb, task.env, task.num_devices)
    assert np.isfinite(m["best_makespan"])
    assert m["best_placement"] is not None


# ------------------------------------------------- paper-scale (slow tier)
@pytest.mark.slow
def test_paper_scale_gnmt_end_to_end():
    """The headline claim: an 8-layer GNMT with >=50k nodes runs the full
    pre-train -> superposition fine-tune -> placement pipeline on one
    host, fits a stated peak-memory bound, and beats round_robin."""
    from benchmarks import large_graph as L
    from benchmarks import common as C

    res = L.run(quick=False, pretrain_iters=4, finetune_iters=4,
                num_samples=2, only=["gnmt-8"])
    row = res["graphs"]["gnmt-8"]
    assert row["nodes"] >= 50_000
    assert np.isfinite(row["gdp"])
    assert row["beats_rr"], (row["gdp"], row["round_robin"])
    # stated peak-memory bound for the whole process (segment-native
    # pipeline: compiled shapes and gathers are O(segment), the audited
    # peak is dominated by PPO residuals + XLA arenas)
    assert res["peak_rss_bytes"] < 24 * 2**30, res["peak_rss_bytes"]


# ------------------------------------------------- memory-aware decode
def test_mask_full_devices_feasible_and_exact():
    """Memory-aware decode: on a memory-tight pool where unconstrained
    sampling from an untrained policy is (almost) never valid, masked
    sampling is feasible by construction; the TF pass applies the same
    mask so AR and TF logp agree; and the segmented masked decode equals
    the monolithic masked decode bit-for-bit."""
    from repro.sim.scheduler import Env as _Env
    g = S.rnnlm(2, time_steps=4)
    topo = p100_topology(4).with_mem_caps(g.total_mem() / 4 * 1.3)
    gb = featurize(g, max_deg=8, topo=topo)
    params = P.init(jax.random.PRNGKey(0), CFG)
    env = _Env(prepare_sim_graph(g, topo, max_deg=16), topo)

    cfg_m = dataclasses.replace(CFG, mask_full_devices=True)
    pl_m, lp_m = P.sample(params, cfg_m, gb, 4, jax.random.PRNGKey(1), 16)
    _, _, valid = env.rewards(pl_m)
    assert bool(np.asarray(valid).all())          # feasible by construction

    lp_tf, _ = P.logp_and_entropy(params, cfg_m, gb, 4, pl_m)
    assert float(jnp.abs(lp_m - lp_tf).max()) < 1e-4   # exact PPO ratios

    cfg_ms = dataclasses.replace(cfg_m, segment=SEG)
    pl_s, lp_s = P.sample(params, cfg_ms, gb, 4, jax.random.PRNGKey(1), 16)
    assert np.array_equal(np.asarray(pl_m), np.asarray(pl_s))
    assert np.array_equal(np.asarray(lp_m), np.asarray(lp_s))


def test_mask_off_is_default_distribution():
    """The flag defaults off and off-mode sampling is untouched by the
    dev_mem_cap plumbing (same placements as before the field existed —
    the golden-pin guarantee)."""
    g = S.rnnlm(2, time_steps=3)
    topo = p100_topology(4)
    gb = featurize(g, max_deg=8, topo=topo)
    params = P.init(jax.random.PRNGKey(0), CFG)
    assert CFG.mask_full_devices is False
    assert gb.dev_mem_cap.shape == (4,)
    pl_a, _ = P.sample(params, CFG, gb, 4, jax.random.PRNGKey(2), 2)
    # a batch whose caps are zeroed-out must sample identically when the
    # flag is off (the cap table is dead weight unless enabled)
    gb_z = gb._replace(dev_mem_cap=jnp.zeros(0))
    pl_b, _ = P.sample(params, CFG, gb_z, 4, jax.random.PRNGKey(2), 2)
    assert np.array_equal(np.asarray(pl_a), np.asarray(pl_b))
