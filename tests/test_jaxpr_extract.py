"""jaxpr extraction: real JAX computations -> GDP-placeable graphs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.featurize import featurize
from repro.graphs.jaxpr_extract import extract
from repro.sim import p100_topology, prepare_sim_graph
from repro.sim.scheduler import Env


def test_extract_mlp_with_scan():
    def mlp(x, w1, w2):
        h = jax.nn.relu(x @ w1)
        def body(c, _):
            return jnp.tanh(c @ w2), None
        h, _ = jax.lax.scan(body, h, None, length=4)
        return jnp.sum(h)

    x = jnp.zeros((8, 64))
    w1 = jnp.zeros((64, 128))
    w2 = jnp.zeros((128, 128))
    g = extract(mlp, x, w1, w2, name="mlp")
    g.validate()
    assert g.num_nodes >= 5
    # scan body flops counted x4 trips
    scan_flops = 4 * 2 * 8 * 128 * 128
    assert g.total_flops() >= scan_flops


def test_extract_grad_graph_larger():
    def loss(x, w):
        return jnp.sum(jnp.tanh(x @ w))
    x, w = jnp.zeros((4, 8)), jnp.zeros((8, 8))
    g_f = extract(loss, x, w, name="f")
    g_b = extract(lambda x, w: jax.grad(loss, argnums=1)(x, w).sum(),
                  x, w, name="b")
    assert g_b.num_nodes > g_f.num_nodes


def test_extracted_model_zoo_graph_placeable():
    """Reduced assigned-arch jaxpr -> GDP environment end to end."""
    from repro.configs import get_reduced
    from repro.models.model import build_model
    cfg = get_reduced("starcoder2-3b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "labels": jnp.zeros((2, 16), jnp.int32)}
    g = extract(model.loss, params, batch, name="starcoder2-reduced")
    g.validate()
    assert g.num_nodes > 20
    topo = p100_topology(2)
    env = Env(prepare_sim_graph(g, topo, max_deg=16), topo)
    gb = featurize(g, max_deg=8, topo=topo)
    rng = np.random.RandomState(0)
    pl = jnp.asarray(rng.randint(0, 2, (4, g.num_nodes)), jnp.int32)
    mk, r, valid = env.rewards(pl)
    assert np.all(np.asarray(mk) > 0)


# ---------------------------------------------------------------------------
# scan expansion (expand=) and the extract_arch disk cache
# ---------------------------------------------------------------------------
def _scan_mlp():
    def mlp(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        h, _ = jax.lax.scan(body, x, None, length=4)
        return jnp.sum(h)
    return mlp, jnp.zeros((8, 32)), jnp.zeros((32, 32))


def test_expand_unrolls_scan_and_conserves_flops():
    fn, x, w = _scan_mlp()
    fused = extract(fn, x, w, name="fused")
    big = extract(fn, x, w, name="big", expand=8)
    # 4 trips of (matmul, tanh) replace one opaque scan node
    assert big.num_nodes > fused.num_nodes
    np.testing.assert_allclose(big.total_flops(), fused.total_flops(),
                               rtol=1e-12)
    big.validate()
    # expand mode emits nodes in topological creation order
    assert np.all(big.src < big.dst)


def test_expand_longer_than_budget_stays_fused():
    fn, x, w = _scan_mlp()
    fused = extract(fn, x, w, name="fused")
    small = extract(fn, x, w, name="small", expand=2)   # length 4 > 2
    assert small.num_nodes == fused.num_nodes
    np.testing.assert_allclose(small.total_flops(), fused.total_flops())


def test_expand_none_is_bit_identical_to_legacy():
    fn, x, w = _scan_mlp()
    g1 = extract(fn, x, w, name="g")
    g2 = extract(fn, x, w, name="g", expand=None)
    for f in ("op_type", "flops", "out_bytes", "mem_bytes", "out_shape",
              "src", "dst"):
        assert np.array_equal(getattr(g1, f), getattr(g2, f)), f


def test_extract_arch_disk_cache_roundtrip(tmp_path):
    from repro.graphs.jaxpr_extract import extract_arch
    kw = dict(reduced=True, mode="loss", seq=16, batch=2,
              cache_dir=str(tmp_path))
    g1 = extract_arch("starcoder2-3b", **kw)
    cached = list(tmp_path.glob("*.npz"))
    assert len(cached) == 1 and ".tmp" not in cached[0].name
    g2 = extract_arch("starcoder2-3b", **kw)   # second call hits the cache
    for f in ("op_type", "flops", "out_bytes", "mem_bytes", "out_shape",
              "src", "dst"):
        assert np.array_equal(getattr(g1, f), getattr(g2, f)), f
    assert g1.name == g2.name


def test_extract_arch_digest_keys_config(tmp_path):
    from repro.graphs.jaxpr_extract import arch_digest
    base = arch_digest("qwen3-8b", mode="grad", seq=64, expand=8)
    assert arch_digest("qwen3-8b", mode="grad", seq=64, expand=8) == base
    assert arch_digest("qwen3-8b", mode="loss", seq=64, expand=8) != base
    assert arch_digest("qwen3-8b", mode="grad", seq=128, expand=8) != base
    assert arch_digest("qwen3-8b", mode="grad", seq=64, expand=16) != base
