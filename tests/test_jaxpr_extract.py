"""jaxpr extraction: real JAX computations -> GDP-placeable graphs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.featurize import featurize
from repro.graphs.jaxpr_extract import extract
from repro.sim import p100_topology, prepare_sim_graph
from repro.sim.scheduler import Env


def test_extract_mlp_with_scan():
    def mlp(x, w1, w2):
        h = jax.nn.relu(x @ w1)
        def body(c, _):
            return jnp.tanh(c @ w2), None
        h, _ = jax.lax.scan(body, h, None, length=4)
        return jnp.sum(h)

    x = jnp.zeros((8, 64))
    w1 = jnp.zeros((64, 128))
    w2 = jnp.zeros((128, 128))
    g = extract(mlp, x, w1, w2, name="mlp")
    g.validate()
    assert g.num_nodes >= 5
    # scan body flops counted x4 trips
    scan_flops = 4 * 2 * 8 * 128 * 128
    assert g.total_flops() >= scan_flops


def test_extract_grad_graph_larger():
    def loss(x, w):
        return jnp.sum(jnp.tanh(x @ w))
    x, w = jnp.zeros((4, 8)), jnp.zeros((8, 8))
    g_f = extract(loss, x, w, name="f")
    g_b = extract(lambda x, w: jax.grad(loss, argnums=1)(x, w).sum(),
                  x, w, name="b")
    assert g_b.num_nodes > g_f.num_nodes


def test_extracted_model_zoo_graph_placeable():
    """Reduced assigned-arch jaxpr -> GDP environment end to end."""
    from repro.configs import get_reduced
    from repro.models.model import build_model
    cfg = get_reduced("starcoder2-3b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "labels": jnp.zeros((2, 16), jnp.int32)}
    g = extract(model.loss, params, batch, name="starcoder2-reduced")
    g.validate()
    assert g.num_nodes > 20
    topo = p100_topology(2)
    env = Env(prepare_sim_graph(g, topo, max_deg=16), topo)
    gb = featurize(g, max_deg=8, topo=topo)
    rng = np.random.RandomState(0)
    pl = jnp.asarray(rng.randint(0, 2, (4, g.num_nodes)), jnp.int32)
    mk, r, valid = env.rewards(pl)
    assert np.all(np.asarray(mk) > 0)
