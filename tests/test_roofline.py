"""benchmarks/roofline.py: MODEL_FLOPS units, dominant-term classing, and
the block-sparse kernels section (cell invariants + cache provenance +
the artifact the nightly gate reads).

The kernels-section fixture is computed once per module — it builds the
real 50k-node gnmt-8 graph and runs the interpret-mode parity cells, so
every test here reads the same section a nightly run would write.
"""
import json
import os

import pytest

from benchmarks import common as C
from benchmarks import roofline as RF
from repro.configs import SHAPES, get_config
from repro.configs.base import list_archs


# ------------------------------------------------------------ model_flops
def test_model_flops_positive_everywhere():
    for arch in list_archs():
        for shape in SHAPES:
            assert RF.model_flops(arch, shape) > 0, (arch, shape)


def test_model_flops_train_counts_fwd_plus_bwd():
    """Train cells charge fb=3 (fwd + bwd) per token; the base term alone
    must therefore exceed 3 * 2 * N_active * tokens - epsilon, and the
    attention term keeps the total strictly above that floor."""
    cfg = get_config("qwen3-8b")
    sh = SHAPES["train_4k"]
    base = 2.0 * cfg.active_param_count() * sh.global_batch * sh.seq_len * 3
    assert RF.model_flops("qwen3-8b", "train_4k") > base


def test_model_flops_prefill_includes_attention_quadratic():
    """Without the S^2 attention term the 32k prefill would equal the
    2*N*D base — the whole point of the term is that it does not."""
    cfg = get_config("qwen3-8b")
    sh = SHAPES["prefill_32k"]
    base = 2.0 * cfg.active_param_count() * sh.global_batch * sh.seq_len
    flops = RF.model_flops("qwen3-8b", "prefill_32k")
    assert flops > base * 1.01


def test_model_flops_decode_charges_per_step_tokens():
    """Decode tokens = batch (one step), not batch * seq: a decode cell
    must come in far below the same arch's prefill cell."""
    assert (RF.model_flops("qwen3-8b", "decode_32k")
            < RF.model_flops("qwen3-8b", "prefill_32k") / 100)


def test_model_flops_enc_dec_branch():
    """whisper-base exercises the enc_dec branch (self-enc + cross attn
    layers added): total stays strictly above the fb=3 base."""
    cfg = get_config("whisper-base")
    assert cfg.enc_dec
    sh = SHAPES["train_4k"]
    base = 2.0 * cfg.active_param_count() * sh.global_batch * sh.seq_len * 3
    assert RF.model_flops("whisper-base", "train_4k") > base


# ---------------------------------------------------------- dominant_term
@pytest.mark.parametrize("tc,tm,tl,want", [
    (3.0, 1.0, 1.0, "compute"),
    (1.0, 3.0, 1.0, "memory"),
    (1.0, 1.0, 3.0, "collective"),
    (2.0, 2.0, 1.0, "compute"),      # tie breaks toward compute
    (1.0, 2.0, 2.0, "memory"),       # then toward memory
    (2.0, 2.0, 2.0, "compute"),
])
def test_dominant_term(tc, tm, tl, want):
    assert RF.dominant_term(tc, tm, tl) == want


# ------------------------------------------------- kernels-section cells
@pytest.fixture(scope="module")
def section():
    return RF.kernels_section(quick=True)


def test_band_attention_cell_invariants():
    for n, w, s in [(512, 32, 64), (8192, 128, 512), (53909, 256, 2048)]:
        c = RF.band_attention_cell(n, window=w, segment=s)
        assert c["segments"] == -(-n // s)
        assert 0 < c["kv_blocks"] <= c["kv_blocks_dense"]
        assert c["kernel_bytes"] <= c["dense_bytes"]
        assert c["bytes_ratio"] == pytest.approx(
            c["kernel_bytes"] / c["dense_bytes"])
    big = RF.band_attention_cell(53909, window=256, segment=2048)
    assert big["kernel_bytes"] < big["dense_bytes"]     # strict at 50k
    assert big["bytes_ratio"] < 0.05


def test_band_attention_cell_monotone_in_window():
    """Wider windows touch more K/V blocks — never fewer."""
    prev = 0
    for w in (32, 64, 128, 256):
        c = RF.band_attention_cell(8192, window=w, segment=512)
        assert c["kv_blocks"] >= prev
        prev = c["kv_blocks"]


def test_csr_maxpool_cell_real_graph():
    from repro.graphs import synthetic as S
    g = S.rnnlm(2, time_steps=6)
    c = RF.csr_maxpool_cell(g)
    assert c["n"] == g.num_nodes and c["edges"] == g.num_edges
    assert 0 <= c["nnz_blocks"] <= c["total_blocks"]
    assert c["kernel_bytes"] <= c["dense_bytes"]
    assert 0 < c["bytes_ratio"] <= 1.0


def test_kernels_section_headline(section):
    hl = section["headline"]
    assert hl["sparse_never_worse"] == 1
    assert hl["sparse_strictly_smaller_50k"] == 1
    assert hl["parity_ok"] == 1
    assert 0 < hl["attn_bytes_ratio_50k"] < 0.05
    assert 0 < hl["maxpool_bytes_ratio_50k"] < 0.05
    par = section["parity"]
    assert par["band_ok"] and par["csr_ok"]
    assert par["band_max_err"] < 2e-5 and par["csr_max_err"] == 0.0


def test_kernels_section_covers_the_50k_cell(section):
    """The gated headline numbers must come from the paper-scale graph,
    not a toy stand-in."""
    assert section["maxpool"]["gnmt-8-50k"]["n"] > 50_000
    assert "n53909_w256_s2048" in section["attention"]


# ----------------------------------------------- provenance + gate wiring
@pytest.fixture()
def tmp_cache(tmp_path, monkeypatch):
    path = os.path.join(tmp_path, "experiments.json")
    monkeypatch.setattr(C, "RESULTS_PATH", path)
    return path


def test_kernels_section_cache_provenance_roundtrip(section, tmp_cache):
    """campaign.py's cache_section call: the section lands in the cache
    with a campaign-grade stamp that run.py's gate accepts; a quick run
    is refused the label entirely."""
    C.cache_section("roofline_kernels", section, campaign_grade=True)
    cached = C.load_cached()
    prov = cached.pop(C.PROVENANCE_KEY)
    assert C.is_campaign_grade("roofline_kernels", cached["roofline_kernels"],
                               prov["roofline_kernels"])
    got = cached["roofline_kernels"]["headline"]
    assert got["attn_bytes_ratio_50k"] == pytest.approx(
        section["headline"]["attn_bytes_ratio_50k"])

    # sub-campaign runs must not write (and hence can never mislabel)
    C.cache_section("roofline_kernels_quick", section, campaign_grade=False)
    assert "roofline_kernels_quick" not in C.load_cached()


def test_kernels_section_without_stamp_is_not_campaign(section):
    assert not C.is_campaign_grade("roofline_kernels", section, None)


def test_cli_artifact_feeds_the_regression_gate(section, tmp_path,
                                                monkeypatch):
    """--kernels --out writes strict JSON in which every
    BENCH_roofline.json metric path of bench_baselines.json resolves —
    the exact contract tools/check_bench_regression.py relies on."""
    monkeypatch.setattr(RF, "kernels_section",
                        lambda quick=True, parity=True: section)
    out = os.path.join(tmp_path, "BENCH_roofline.json")
    RF.cli(["--kernels", "--out", out])
    with open(out) as f:
        doc = json.load(f)
    base = os.path.join(os.path.dirname(RF.__file__),
                        "bench_baselines.json")
    with open(base) as f:
        metrics = [m for m in json.load(f)["metrics"]
                   if m["file"] == "BENCH_roofline.json"]
    assert len(metrics) == 5
    for m in metrics:
        node = doc
        for part in m["path"].split("."):
            assert part in node, (m["path"], part)
            node = node[part]
        assert isinstance(node, (int, float))
