"""Hierarchical coarsen→place→refine: the pipeline's safety contract.

Pins the four properties ISSUE 10 promises:
  * coarsening conserves total flops / memory / cross-partition bytes;
  * refinement never violates per-device memory caps (structural: caps
    are reduced by outside-window residency before the decode);
  * coarse+refine makespan is monotonically <= coarse-only makespan
    (accept-only-if-strictly-better);
  * the streamed (out-of-core) featurization path is bit-identical to
    the in-RAM featurizer on small graphs.
"""
import dataclasses

import jax
import numpy as np

from repro.core import baselines as B
from repro.core import policy
from repro.core.featurize import featurize, featurize_window
from repro.core.policy import PolicyConfig
from repro.core.scale import ScaleConfig
from repro.graphs import synthetic as S
from repro.graphs.shards import open_shards, write_shards
from repro.hier import coarsen, place_hierarchical, refine
from repro.sim import p100_topology, prepare_sim_graph
from repro.sim.scheduler import Env, SimConfig

SMALL = PolicyConfig(hidden=16, gnn_layers=1, op_emb=8, placer_layers=1,
                     heads=2, ffn=32, window=16, max_devices=4)


def _graph():
    return S.gnmt(2, time_steps=6)


def _topo(g, d=4, slack=2.5):
    return p100_topology(d).with_mem_caps(g.total_mem() / d * slack)


# ---------------------------------------------------------------------------
# coarsening
# ---------------------------------------------------------------------------
def test_coarsen_conserves_costs():
    g = _graph()
    c = coarsen(g, target_nodes=16)
    assert c.coarse.num_nodes == 16
    np.testing.assert_allclose(c.coarse.total_flops(), g.total_flops(),
                               rtol=1e-12)
    np.testing.assert_allclose(c.coarse.mem_bytes.sum(), g.mem_bytes.sum(),
                               rtol=1e-12)
    # every fine byte that crosses a partition boundary lands in exactly
    # one aggregated coarse edge
    w = g.out_bytes[g.src].astype(np.float64)
    cross = c.part[g.src] != c.part[g.dst]
    np.testing.assert_allclose(c.edge_bytes.sum(), w[cross].sum(),
                               rtol=1e-12)


def test_coarsen_partitions_are_contiguous_and_cover():
    g = _graph()
    c = coarsen(g, target_nodes=8)
    assert c.starts[0] == 0 and c.starts[-1] == g.num_nodes
    assert np.all(np.diff(c.starts) >= 1)
    # part is the step function of starts; expand() inverts it
    for p in range(c.num_partitions):
        lo, hi = c.window(p)
        assert np.all(c.part[lo:hi] == p)
    cp = np.arange(c.num_partitions, dtype=np.int32) % 3
    lifted = c.expand(cp)
    assert lifted.shape == (g.num_nodes,)
    assert np.array_equal(lifted, cp[c.part])


def test_coarsen_deterministic_and_shards_equal_inram(tmp_path):
    g = _graph()
    c1 = coarsen(g, target_nodes=16)
    c2 = coarsen(g, target_nodes=16)
    assert c1.fingerprint == c2.fingerprint
    # a different contraction is a different provenance key
    assert coarsen(g, target_nodes=8).fingerprint != c1.fingerprint
    # the shard-backed path must produce the identical coarsening
    sh = write_shards(g, str(tmp_path / "sh"), shard_nodes=64)
    c3 = coarsen(sh, target_nodes=16)
    assert c3.fingerprint == c1.fingerprint
    assert np.array_equal(c3.part, c1.part)


# ---------------------------------------------------------------------------
# streamed featurization == in-RAM featurization
# ---------------------------------------------------------------------------
def test_featurize_window_bit_identical_to_inram(tmp_path):
    g0 = _graph()
    sh = write_shards(g0, str(tmp_path / "sh"), shard_nodes=64)
    g = sh.load_graph()          # canonical (dst, src)-sorted edge order
    topo = _topo(g)
    ref = featurize(g, max_deg=8, topo=topo)
    got = featurize_window(sh, 0, g.num_nodes, max_deg=8, topo=topo)
    for field in ("op", "feats", "nbr_idx", "nbr_mask", "node_mask",
                  "mem_frac", "comp_frac", "dev_feats", "dev_mem_cap"):
        a, b = np.asarray(getattr(ref, field)), np.asarray(getattr(got, field))
        assert a.dtype == b.dtype and a.shape == b.shape, field
        assert np.array_equal(a, b), field
    assert got.num_nodes == ref.num_nodes


def test_featurize_window_masks_out_of_window_neighbors(tmp_path):
    g0 = _graph()
    sh = write_shards(g0, str(tmp_path / "sh"), shard_nodes=64)
    topo = _topo(sh.load_graph())
    lo, hi, pad = 32, 96, 128
    gb = featurize_window(sh, lo, hi, max_deg=8, topo=topo, pad_to=pad)
    assert gb.op.shape[0] == pad and gb.num_nodes == hi - lo
    idx = np.asarray(gb.nbr_idx)
    mask = np.asarray(gb.nbr_mask)
    # every unmasked neighbor is a window-local index; masked slots point
    # at the sentinel row
    assert np.all(idx[mask > 0] < hi - lo)
    assert np.all(idx[mask == 0] == pad)


# ---------------------------------------------------------------------------
# refinement
# ---------------------------------------------------------------------------
def test_refine_monotone_and_cap_safe():
    g = _graph()
    topo = _topo(g)
    env = Env.from_config(prepare_sim_graph(g, topo), topo, SimConfig())
    params = policy.init(jax.random.PRNGKey(0), SMALL)
    start = np.asarray(B.round_robin(g, topo), np.int32)
    mk0, _, ok0 = env.rewards(start[None])
    assert bool(ok0[0])

    res = refine(params, SMALL, env, g, topo, start,
                 key=jax.random.PRNGKey(1), window=64, num_samples=2)
    traj = np.asarray(res.trajectory)
    assert traj[0] == float(mk0[0])
    # accept-only-if-strictly-better => nonincreasing, ends at makespan
    assert np.all(np.diff(traj) <= 0)
    assert res.makespan == traj[-1] <= traj[0]
    # final placement is cap-safe on every device
    usage = np.bincount(res.placement, weights=g.mem_bytes,
                        minlength=topo.num_devices)
    assert np.all(usage <= topo.mem_caps + 1e-6)
    _, _, ok = env.rewards(res.placement[None])
    assert bool(ok[0])


def test_refine_max_windows_bounds_sweep():
    g = _graph()
    topo = _topo(g)
    env = Env.from_config(prepare_sim_graph(g, topo), topo, SimConfig())
    params = policy.init(jax.random.PRNGKey(0), SMALL)
    start = np.asarray(B.round_robin(g, topo), np.int32)
    res = refine(params, SMALL, env, g, topo, start,
                 key=jax.random.PRNGKey(1), window=64, num_samples=2,
                 max_windows=1)
    assert res.windows == 1
    assert len(res.trajectory) == 2


# ---------------------------------------------------------------------------
# full pipeline
# ---------------------------------------------------------------------------
def test_place_hierarchical_end_to_end(tmp_path):
    g = _graph()
    topo = _topo(g)
    sc = ScaleConfig(coarse_target=24, refine_window=64)
    res = place_hierarchical(g, topo, pcfg=SMALL, scale=sc,
                             iterations=2, num_samples=2, seed=0,
                             log_every=0)
    assert res.valid
    assert res.placement.shape == (g.num_nodes,)
    assert res.placement.dtype == np.int32
    # coarse+refine <= coarse-only, and the trajectory records the path
    assert res.makespan <= res.trajectory[0]
    assert res.trajectory[-1] == res.makespan
    assert res.coarsening.num_partitions <= 24
    assert len(res.coarsening.fingerprint) == 64
    # shard-backed source takes the same pipeline to the same contract
    sh = write_shards(g, str(tmp_path / "sh"), shard_nodes=64)
    res2 = place_hierarchical(sh, topo, pcfg=SMALL, scale=sc,
                              iterations=2, num_samples=2, seed=0,
                              log_every=0)
    assert res2.valid and res2.makespan <= res2.trajectory[0]
    assert res2.coarsening.fingerprint == res.coarsening.fingerprint
