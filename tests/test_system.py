"""End-to-end system behaviour: GDP search loop improves placements and the
whole pipeline (graph -> featurize -> policy -> simulator -> PPO -> export)
holds together."""
import jax.numpy as jnp
import numpy as np

from repro.core import baselines as B
from repro.core.export import placement_to_stage_plan
from repro.core.featurize import featurize
from repro.core.policy import PolicyConfig
from repro.core.ppo import PPOConfig, PPOTrainer
from repro.graphs import synthetic as S
from repro.sim import p100_topology, prepare_sim_graph
from repro.sim.scheduler import Env

PCFG = PolicyConfig(hidden=32, gnn_layers=2, placer_layers=1, ffn=64,
                    window=32, max_devices=8)
PPO = PPOConfig(num_samples=16, lr=2e-3, epochs=2, canonicalize=True,
                per_node_credit=False)


def _task(g, d=2, tighten=1.8):
    topo = p100_topology(d).with_mem_caps(g.total_mem() / d * tighten)
    sg = prepare_sim_graph(g, topo, max_deg=16)
    return topo, Env(sg, topo, shaped_reward=True), Env(sg, topo), \
        featurize(g, max_deg=8, topo=topo)


def test_end_to_end_search_improves():
    g = S.inception(modules=4)
    topo, env, env_true, gb = _task(g)
    tr = PPOTrainer(PCFG, PPO, seed=0)
    first = tr.iteration("incep", gb, env, 2)
    start = first["best_makespan"]
    best = start
    for _ in range(14):
        m = tr.iteration("incep", gb, env, 2)
        best = min(best, m["best_makespan"])
    assert np.isfinite(best)
    assert best <= start                      # search never regresses
    # the found placement beats the random-placement average
    rand = []
    for s in range(4):
        mk, _, ok = env_true.rewards(
            jnp.asarray(B.random_placement(g, topo, s))[None])
        if bool(ok[0]):
            rand.append(float(mk[0]))
    assert best < np.mean(rand)


def test_end_to_end_batch_and_transfer():
    """GDP-batch trains on two families; zero-shot samples on a third are
    valid and the stage-plan export consumes the result."""
    g1, g2, g3 = (S.rnnlm(2, time_steps=3), S.inception(modules=3),
                  S.wavenet(1, 4))
    tasks = []
    for g in (g1, g2):
        topo, env, env_true, gb = _task(g)
        tasks.append((g.name, gb, env, 2))
    tr = PPOTrainer(PCFG, PPO, seed=0)
    tr.train(tasks, iterations=4, log_every=0)

    topo3, env3, env3_true, gb3 = _task(g3)
    best = tr.best_of_samples(gb3, env3_true, 2, 8)
    assert np.isfinite(best) and best > 0

    from repro.core import policy as P
    pl = P.greedy(tr.state.params, PCFG, gb3, 2)
    plan = placement_to_stage_plan(g3, np.asarray(pl), 2)
    assert plan.num_stages >= 1
    assert plan.stage_of_node.shape == (g3.num_nodes,)
