"""Graph IR invariants (unit + hypothesis property tests)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.graph import DataflowGraph, GraphBuilder, topo_relabel
from repro.graphs import synthetic as S


ALL_FAMILIES = [
    lambda: S.rnnlm(2, time_steps=4),
    lambda: S.gnmt(2, time_steps=3),
    lambda: S.transformer_xl(2, segments=2),
    lambda: S.inception(modules=3),
    lambda: S.amoebanet(cells=3),
    lambda: S.wavenet(1, 4),
]


@pytest.mark.parametrize("mk", ALL_FAMILIES)
def test_families_valid(mk):
    g = mk()
    g.validate()
    assert g.num_nodes > 10
    assert g.total_flops() > 0
    # edges strictly topological
    assert np.all(g.src < g.dst)


def test_builder_rejects_forward_deps():
    b = GraphBuilder("x")
    a = b.add("input", (1,))
    with pytest.raises(ValueError):
        b.add("matmul", (1,), deps=[5])


def test_neighbors_padding():
    g = S.rnnlm(2, time_steps=4)
    idx, mask = g.in_neighbors_padded(max_deg=4)
    assert idx.shape == mask.shape
    assert idx.shape[1] <= 4
    # sentinel only where mask == 0
    assert np.all((idx == g.num_nodes) == ~mask)
    # masked entries are real in-edges
    for v in range(g.num_nodes):
        real = set(g.src[g.dst == v].tolist())
        listed = set(idx[v][mask[v]].tolist())
        assert listed <= real


@settings(max_examples=20, deadline=None)
@given(st.integers(5, 40), st.integers(0, 100), st.integers(0, 10 ** 6))
def test_topo_relabel_random(n, extra_edges, seed):
    rng = np.random.RandomState(seed)
    # random DAG: edges only i<j
    src, dst = [], []
    for _ in range(extra_edges):
        i, j = sorted(rng.choice(n, 2, replace=False))
        src.append(i)
        dst.append(j)
    perm = rng.permutation(n)
    # relabel nodes by perm (breaks topological order)
    src_p = [int(perm[s]) for s in src]
    dst_p = [int(perm[d]) for d in dst]
    shape = np.ones((n, 4), np.int64)
    g = topo_relabel("rand", np.zeros(n, np.int32), np.ones(n), np.ones(n),
                     np.ones(n), shape, np.array(src_p, np.int64),
                     np.array(dst_p, np.int64))
    g.validate()
    assert g.num_nodes == n
    assert g.num_edges == len(src_p)      # duplicates preserved
